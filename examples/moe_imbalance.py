"""MoE expert-load imbalance detected by the paper's dissimilarity
analysis, and fixed by the aux-loss knob — the framework-native analogue of
ST's dynamic load dispatching (DESIGN.md §4).

Experts play the role of the paper's processes: each expert's per-layer
token-count vector is a performance vector; routing collapse shows up as
multiple OPTICS clusters.

    PYTHONPATH=src python examples/moe_imbalance.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import MoEConfig, get_arch
from repro.core import RegionTree, find_dissimilarity_bottlenecks
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig


def expert_load_clusters(history, n_experts):
    """Per-expert vectors over (layers × recent steps) -> OPTICS pass."""
    counts = [h["expert_counts"] for h in history if "expert_counts" in h]
    if not counts:
        return None
    mat = np.stack(counts[-8:])           # (steps, L, E)
    vecs = mat.transpose(2, 0, 1).reshape(n_experts, -1).astype(np.float64)
    tree = RegionTree("moe")
    rids = []
    for j in range(vecs.shape[1]):
        rids.append(tree.add(f"slot{j}").region_id)
    return find_dissimilarity_bottlenecks(tree, vecs, rids)


def run(aux_weight: float, steps: int = 40):
    base = get_arch("mixtral-8x22b").smoke
    cfg = base.with_(moe=MoEConfig(
        n_experts=4, top_k=2, n_shared=0, d_ff=64, capacity_factor=2.0,
        sharding="tp", aux_loss_weight=aux_weight))
    trainer = Trainer(
        cfg, AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=steps),
        DataConfig(seq_len=32, global_batch=4, vocab=cfg.vocab),
        TrainerConfig(steps=steps, ckpt_dir=None, seed=0))
    # inject a routing collapse: bias every router strongly toward expert 0
    p = trainer.params
    router = p["layers"]["moe"]["router"]
    p["layers"]["moe"]["router"] = router.at[..., 0].add(3.0)
    hist = trainer.run()
    rep = expert_load_clusters(hist[:4], cfg.moe.n_experts)

    def cv_at(h):
        loads = h["expert_counts"].sum(axis=0)
        return float(loads.std() / loads.mean())

    return rep, cv_at(hist[0]), cv_at(hist[-1]), hist[-1]["loss"]


def main():
    print("== aux_loss_weight = 0 (no load balancing) ==")
    rep0, cv0_start, cv0_end, loss0 = run(0.0)
    print(f"expert-load clusters (early steps): {rep0.baseline.n_clusters}")
    print(f"load CV: start {cv0_start:.3f} -> end {cv0_end:.3f}  "
          f"loss {loss0:.3f}")
    if rep0.exists:
        print("-> dissimilarity bottleneck: expert load imbalance detected "
              "(the paper's ST scenario, expert-parallel form)")

    print("\n== aux_loss_weight = 0.05 (the paper's 'dynamic dispatching' "
          "fix, MoE-style) ==")
    rep1, cv1_start, cv1_end, loss1 = run(0.05)
    print(f"load CV: start {cv1_start:.3f} -> end {cv1_end:.3f}  "
          f"loss {loss1:.3f}")
    print(f"\nwith the aux loss the collapse recovers faster/further: "
          f"{cv0_end:.3f} (no aux) vs {cv1_end:.3f} (aux)")


if __name__ == "__main__":
    main()
