"""The paper's ST case study (§6.1), end to end: locate the dissimilarity
and disparity bottlenecks, uncover root causes with the rough-set engine,
apply the paper's two fixes, and re-analyze (Fig. 14).

    PYTHONPATH=src python examples/st_scenario.py
"""
from repro.core import AutoAnalyzer, format_matrix, render
from repro.scenarios import st_scenario, st_total_time


def analyze(title, **kw):
    tree, rm = st_scenario(**kw)
    res = AutoAnalyzer(tree).analyze(rm)
    print(f"===== {title} =====")
    print(render(tree, res))
    print(f"total wall time: {st_total_time(rm):.1f}s")
    print()
    return res


def main():
    res = analyze("ST, original")
    if res.dissimilarity_table is not None:
        print("discernibility matrix (dissimilarity decision table):")
        print(format_matrix(res.dissimilarity_table))
        print()

    base = st_total_time(st_scenario()[1])
    analyze("ST, dynamic load dispatching (fixes region 11 imbalance)",
            optimize_dissimilarity=True)
    analyze("ST, buffered I/O + loop blocking (fixes regions 8 & 11)",
            optimize_disparity=True)
    analyze("ST, both fixes", optimize_dissimilarity=True,
            optimize_disparity=True)
    both = st_total_time(st_scenario(optimize_dissimilarity=True,
                                     optimize_disparity=True)[1])
    print(f"Fig. 14 analogue: overall speedup {100 * (base / both - 1):.0f}%"
          f" (paper: +170%)")


if __name__ == "__main__":
    main()
