"""Quickstart: train a small LM for a few steps with AutoAnalyzer attached,
then print the paper-style performance-debugging report.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_arch
from repro.core import AutoAnalyzer, RegionTree, TimedRegionRunner, render
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig


def main():
    cfg = get_arch("st-100m").smoke
    trainer = Trainer(
        cfg,
        AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=30),
        DataConfig(seq_len=64, global_batch=4, vocab=cfg.vocab),
        TrainerConfig(steps=30, ckpt_dir=None),
    )
    hist = trainer.run()
    print(f"trained {len(hist)} steps: "
          f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")

    # -- AutoAnalyzer over an instrumented region tree --------------------
    # Regions = the step phases; shards emulate SPMD processes.
    tree = RegionTree("train_step")
    api = trainer.api

    def fwd_embed(state, batch):
        from repro.models.layers import embed
        return embed(state["params"]["embed"], cfg, batch["tokens"])

    def loss_region(state, batch):
        loss, _ = api.loss_fn(state["params"], batch)
        return loss

    tree.add("embed", fn=lambda s, b: (s, fwd_embed(s, b))[0])
    tree.add("loss", fn=lambda s, b: (s, loss_region(s, b))[0])

    from repro.data import device_batch
    shards = 4
    dcfg = DataConfig(seq_len=64, global_batch=shards, vocab=cfg.vocab)
    batches = [
        {k: v[i:i + 1] for k, v in device_batch(dcfg, 0).items()}
        for i in range(shards)
    ]
    states = [{"params": trainer.params} for _ in range(shards)]
    runner = TimedRegionRunner(tree)
    rm = runner.run(states, batches)
    res = AutoAnalyzer(tree).analyze(rm)
    print()
    print(render(tree, res))


if __name__ == "__main__":
    main()
