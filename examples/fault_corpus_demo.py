"""Fault-injection corpus walkthrough: plant a known bottleneck, watch
AutoAnalyzer recover it, and compare against the ground truth — the
paper's §6 validation loop in miniature.

    PYTHONPATH=src python examples/fault_corpus_demo.py [entry-name]
"""
import sys

from repro.core import AutoAnalyzer, render
from repro.scenarios import CORPUS, corpus_entries, score_verdict


def show(name: str) -> None:
    entry = CORPUS[name]
    print(f"== {entry.name} [{entry.backend}] — {entry.description}")
    print(f"   planted: {sorted(entry.truth.bottleneck_paths)} "
          f"({entry.truth.kind}); "
          f"causes {sorted(entry.truth.cause_attributes) or '(any)'}")
    tree, collector = entry.build(seed=0)
    analyzer = AutoAnalyzer(tree, **dict(entry.analyzer_kw))
    result = analyzer.analyze_collector(collector)
    print(render(tree, result))
    r = score_verdict(entry, result.verdict)
    print(f"   verdict paths: {sorted(r.found)}")
    print(f"   precision {r.precision:.2f}  recall {r.recall:.2f}  "
          f"cause recall {r.cause_recall:.2f}\n")


if __name__ == "__main__":
    names = sys.argv[1:] or ["st/data-skew-cr11", "st/io-hotspot-cr8",
                             "moe/mixtral-expert-hotspot"]
    for name in names:
        if name not in CORPUS:
            known = ", ".join(e.name for e in corpus_entries())
            raise SystemExit(f"unknown entry {name!r}; known: {known}")
        show(name)
