"""Perf triage of a dry-run cell with the paper's disparity machinery:
per-phase static costs -> CRNM severity bands -> rough-set root causes.
Self-contained on CPU with 8 placeholder devices.

    PYTHONPATH=src python examples/dryrun_triage.py [--arch chatglm3-6b]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b")
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    from repro.configs import get_arch
    import repro.configs.base as base
    from repro.launch.mesh import make_mesh
    from repro.launch.static_analyzer import report_cell

    mesh = make_mesh((2, 4), ("data", "model"))
    shape = base.InputShape("triage", args.seq, args.batch, "train")
    cfg = get_arch(args.arch).smoke.with_(dtype="float32",
                                          param_dtype="float32")
    print(report_cell(cfg, shape, mesh))


if __name__ == "__main__":
    main()
