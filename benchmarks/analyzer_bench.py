"""Scaling benchmarks for the AutoAnalyzer hot path.

A deterministic grid over shards m × regions n for the four analyzer
kernels — simplified-OPTICS clustering, the full Algorithm 2 dissimilarity
search, the disparity search, and rough-set reducts — so the cost of
per-rank similarity analysis stays measured as process counts grow
(thousands of shards; see docs/performance.md).

``scripts/run_bench.py`` drives these into ``BENCH_analyzer.json`` and
gates regressions against the committed baseline; ``benchmarks/run.py
--only analyzer`` prints the same rows as CSV.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.core import (RegionTree, find_disparity_bottlenecks,
                        find_dissimilarity_bottlenecks, get_distance_backend,
                        optics_cluster)
from repro.core.roughset import DecisionTable

# Grid points: shards m in {8..16384} x regions n in {16..512}.  The smoke
# grid is the tier-1 CI lane (sub-second); default is the committed
# baseline's grid.  The m >= 8192 rows exist to pin the memory-bounded
# claim: the old eager-D² path would need 0.5-2 GB per trial sweep there.
_MN_SMOKE = [(8, 16), (32, 16)]
_MN_DEFAULT = [(m, n)
               for m in (8, 32, 128, 512, 2048)
               for n in (16, 64, 128, 512)] + \
              [(8192, 64), (8192, 128), (16384, 64), (16384, 128)]
# Distance-backend seed-row fetches (8 seeds, the shape Algorithm 2's
# lockstep rounds issue); jax/pallas rows appear when jax imports.
_SEEDROWS = [(2048, 128), (16384, 128)]
# Device-lane Algorithm 2: the same search as the algo2/ rows but routed
# through the lockstep device path (ISSUE 9).  The m=16384/n=128 jax row
# is the committed reference for the >= 5x speedup claim vs the numpy
# algo2/m16384/n128 baseline.  Pallas runs in interpret mode off-TPU, so
# it only appears at the small shape (timing the orchestration, not the
# kernel; on-TPU it compiles to the tiled kernel proper).
_ALGO2_DEVICE = [(2048, 128), (16384, 128)]
GRIDS: Dict[str, Dict[str, list]] = {
    "smoke": {"mn": _MN_SMOKE, "disparity_n": [16, 64],
              "reducts_attrs": [5, 8], "seedrows": [],
              "algo2_device": [(32, 16)]},
    "default": {"mn": _MN_DEFAULT, "disparity_n": [16, 64, 128, 512],
                "reducts_attrs": [5, 10, 14], "seedrows": _SEEDROWS,
                "algo2_device": _ALGO2_DEVICE},
}
# interpret-mode pallas above this m is orchestration noise, not signal
_PALLAS_BENCH_MAX_M = 2048


def cluster_workload(m: int, n: int, seed: int = 0) -> np.ndarray:
    """(m, n) near-balanced measurement matrix with one straggling shard
    block — several clusters, like real dissimilar runs."""
    rng = np.random.default_rng(seed)
    T = 1.0 + 0.05 * rng.random((m, n))
    T[: max(1, m // 8), n // 3] *= 6.0
    return T


def algo2_workload(m: int, n: int,
                   seed: int = 0) -> Tuple[RegionTree, np.ndarray, List[int]]:
    """Flat n-region tree + matrix with a planted single-region straggler:
    Algorithm 2 walks every depth-1 region and pins one CCR."""
    tree = RegionTree("bench")
    for j in range(1, n + 1):
        tree.add(f"cr{j}")
    return tree, cluster_workload(m, n, seed), list(range(1, n + 1))


def disparity_workload(n: int,
                       seed: int = 0) -> Tuple[RegionTree, np.ndarray,
                                               List[int]]:
    tree = RegionTree("bench")
    for j in range(1, n + 1):
        tree.add(f"cr{j}")
    rng = np.random.default_rng(seed)
    vals = 0.01 + 0.02 * rng.random(n)
    vals[n // 3] = 0.9
    return tree, vals, list(range(1, n + 1))


def reducts_workload(n_attrs: int, n_rows: int = 24,
                     seed: int = 0) -> DecisionTable:
    rng = np.random.default_rng(seed)
    rows = [tuple(int(x) for x in rng.integers(0, 2, n_attrs))
            for _ in range(n_rows)]
    decisions = [int(x) for x in rng.integers(0, 2, n_rows)]
    return DecisionTable([f"a{i}" for i in range(n_attrs)], rows, decisions)


def _best_of(fn: Callable[[], object], repeat: int) -> float:
    fn()      # untimed warmup: first-touch page faults, BLAS spin-up
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run_grid(grid: str = "default", repeat: int = 3,
             seed: int = 0) -> Dict[str, Dict[str, float]]:
    """Time every grid entry (best of ``repeat``); returns
    ``{entry_name: {dims..., "seconds": t}}``."""
    spec = GRIDS[grid]
    entries: Dict[str, Dict[str, float]] = {}

    for m, n in spec["mn"]:
        T = cluster_workload(m, n, seed)
        entries[f"cluster/m{m}/n{n}"] = {
            "m": m, "n": n,
            "seconds": _best_of(lambda: optics_cluster(T), repeat)}

    for m, n in spec["mn"]:
        tree, T, rids = algo2_workload(m, n, seed)
        entries[f"algo2/m{m}/n{n}"] = {
            "m": m, "n": n,
            "seconds": _best_of(
                lambda: find_dissimilarity_bottlenecks(tree, T, rids),
                repeat)}

    for m, n in spec.get("algo2_device", ()):
        tree, T, rids = algo2_workload(m, n, seed)
        for backend in _device_backends(m):
            entries[f"algo2/m{m}/n{n}/{backend}"] = {
                "m": m, "n": n, "requires": "jax",
                "seconds": _best_of(
                    lambda: find_dissimilarity_bottlenecks(
                        tree, T, rids, backend=backend),
                    repeat)}

    for n in spec["disparity_n"]:
        tree, vals, rids = disparity_workload(n, seed)
        entries[f"disparity/n{n}"] = {
            "n": n,
            "seconds": _best_of(
                lambda: find_disparity_bottlenecks(tree, vals, rids),
                repeat)}

    for a in spec["reducts_attrs"]:
        table = reducts_workload(a, seed=seed)
        entries[f"reducts/a{a}"] = {
            "attrs": a,
            "seconds": _best_of(table.reducts, repeat)}

    for m, n in spec.get("seedrows", ()):
        W = cluster_workload(m, n, seed)
        sq = np.einsum("ij,ij->i", W, W)
        for backend in _seedrow_backends():
            be = get_distance_backend(backend)
            handle = be.prepare(W, sq)
            idx = list(range(8))
            be.seed_rows(handle, idx)      # warm (jit/pallas compile)
            entry = {
                "m": m, "n": n,
                "seconds": _best_of(
                    lambda: be.seed_rows(handle, idx), repeat)}
            if backend != "numpy":
                # Lets run_bench.py --check skip (not fail on) these
                # entries on machines without jax.
                entry["requires"] = "jax"
            entries[f"seedrows/m{m}/n{n}/{backend}"] = entry

    return entries


def _seedrow_backends() -> List[str]:
    try:
        import jax  # noqa: F401
        return ["numpy", "jax", "pallas"]
    except ImportError:
        return ["numpy"]


def _device_backends(m: int) -> List[str]:
    try:
        import jax  # noqa: F401
    except ImportError:
        return []
    return ["jax"] + (["pallas"] if m <= _PALLAS_BENCH_MAX_M else [])


def all_rows() -> List[Tuple[str, float, str]]:
    """(name, us_per_call, derived) rows for benchmarks/run.py CSV."""
    entries = run_grid("default", repeat=3)
    return [(name, e["seconds"] * 1e6,
             "x".join(str(int(e[d])) for d in ("m", "n", "attrs") if d in e))
            for name, e in entries.items()]
