"""Benchmark harness: one function per paper table/figure plus kernel and
roofline reports.  Prints ``name,us_per_call,derived`` CSV.

Usage: PYTHONPATH=src python -m benchmarks.run [--only paper|kernels|roofline|analyzer]
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=["paper", "kernels", "roofline",
                                       "analyzer"],
                    default=None)
    args = ap.parse_args()
    from benchmarks import (analyzer_bench, kernel_bench, paper_tables,
                            roofline_report)
    rows = []
    if args.only in (None, "paper"):
        rows += paper_tables.all_rows()
    if args.only in (None, "kernels"):
        rows += kernel_bench.all_rows()
    if args.only in (None, "roofline"):
        rows += roofline_report.all_rows()
    if args.only in (None, "analyzer"):
        rows += analyzer_bench.all_rows()
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == '__main__':
    main()
