"""Roofline summary benchmark: reads the dry-run sweep results (if present
under results/) and emits one row per (arch × shape) cell with the three
terms — the framework-side 'table' feeding EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os
from typing import List, Tuple

Row = Tuple[str, float, str]

RESULTS_GLOB = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results", "*.json")


def load_cells() -> List[dict]:
    cells = {}
    for path in sorted(glob.glob(RESULTS_GLOB)):
        try:
            with open(path) as f:
                data = json.load(f)
        except Exception:
            continue
        if isinstance(data, dict):
            data = [data]
        for r in data:
            if r.get("ok") and "roofline" in r:
                cells[(r["arch"], r["shape"], r["mesh"])] = r
    return [cells[k] for k in sorted(cells)]


def all_rows() -> List[Row]:
    rows: List[Row] = []
    for r in load_cells():
        t = r["roofline"]
        derived = (f"mesh={r['mesh']};dominant={t['dominant']};"
                   f"compute_s={t['compute_s']:.3e};"
                   f"memory_s={t['memory_s']:.3e};"
                   f"collective_s={t['collective_s']:.3e};"
                   f"useful={t['useful_ratio']:.3f};"
                   f"roofline={100*t['roofline_fraction']:.1f}%")
        rows.append((f"roofline_{r['arch']}_{r['shape']}",
                     t["bound_s"] * 1e6, derived))
    if not rows:
        rows.append(("roofline_summary", 0.0,
                     "no dry-run results found (run repro.launch.dryrun)"))
    return rows
