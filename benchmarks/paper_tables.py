"""One benchmark per paper table/figure (§6).  Each returns
(name, us_per_call, derived) rows: us_per_call times the analysis itself,
derived carries the reproduced result."""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core import (AutoAnalyzer, COMM_BYTES, FLOPS, HBM_INTENSITY,
                        HOST_BYTES, WALL_TIME, optics_cluster, paper_table2,
                        paper_table3, paper_table4)
from repro.scenarios import (mpibzip2_scenario, npar1way_scenario,
                             st_scenario, st_total_time)

Row = Tuple[str, float, str]


def _timed(fn, n=5):
    fn()
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn()
    dt = (time.perf_counter() - t0) / n
    return out, dt * 1e6


def fig9_st_dissimilarity() -> Row:
    tree, rm = st_scenario()
    az = AutoAnalyzer(tree)
    res, us = _timed(lambda: az.analyze(rm))
    d = res.dissimilarity
    derived = (f"clusters={d.baseline.n_clusters};CCCR={d.cccrs};"
               f"severity={d.severity:.4f}")
    return ("fig9_st_dissimilarity", us, derived)


def fig11_instruction_variance() -> Row:
    tree, rm = st_scenario()
    flops, us = _timed(lambda: rm.vectors(FLOPS, [11]))
    ratio = float(flops.max() / flops.min())
    return ("fig11_region11_instruction_variance", us,
            f"max/min={ratio:.2f}")


def fig12_13_st_disparity() -> Row:
    tree, rm = st_scenario()
    az = AutoAnalyzer(tree)
    res, us = _timed(lambda: az.analyze(rm))
    sev = res.disparity.severities
    vh = sorted(r for r, s in sev.items() if s == 4)
    crnm11 = res.disparity.values[11]
    return ("fig12_13_st_disparity", us,
            f"very_high={vh};CCCR={res.disparity.cccrs};"
            f"crnm11={crnm11:.3f}")


def table2_weather_example() -> Row:
    t = paper_table2()
    reds, us = _timed(lambda: t.reducts())
    return ("table2_rough_set_example", us,
            "reducts=" + "|".join(",".join(sorted(r)) for r in reds))


def table3_dissimilarity_roots() -> Row:
    t = paper_table3()
    reds, us = _timed(lambda: t.reducts())
    return ("table3_dissimilarity_core", us,
            "core=" + ",".join(sorted(reds[0])))


def table4_disparity_roots() -> Row:
    t = paper_table4()
    reds, us = _timed(lambda: t.reducts())
    return ("table4_disparity_core", us,
            "core=" + ",".join(sorted(reds[0])))


def fig14_st_optimization() -> Row:
    def run():
        base = st_total_time(st_scenario()[1])
        disp = st_total_time(st_scenario(optimize_disparity=True)[1])
        dis = st_total_time(st_scenario(optimize_dissimilarity=True)[1])
        both = st_total_time(st_scenario(optimize_dissimilarity=True,
                                         optimize_disparity=True)[1])
        return base, disp, dis, both

    (base, disp, dis, both), us = _timed(run, n=2)
    return ("fig14_st_before_after", us,
            f"disparity=+{100*(base/disp-1):.0f}%;"
            f"dissimilarity=+{100*(base/dis-1):.0f}%;"
            f"both=+{100*(base/both-1):.0f}% (paper:+90/+40/+170)")


def npar1way_analysis() -> Row:
    tree, rm = npar1way_scenario()
    az = AutoAnalyzer(tree)
    res, us = _timed(lambda: az.analyze(rm))
    causes = sorted(res.disparity_causes[0]) if res.disparity_causes else []
    return ("sec6_2_npar1way", us,
            f"dissim={res.dissimilarity.exists};"
            f"CCR={res.disparity.ccrs};causes={causes}")


def npar1way_optimization() -> Row:
    def run():
        _, rm = npar1way_scenario()
        _, rm2 = npar1way_scenario(optimize=True)
        d3 = 1 - rm2.region_mean(FLOPS, 3) / rm.region_mean(FLOPS, 3)
        d12 = 1 - rm2.region_mean(FLOPS, 12) / rm.region_mean(FLOPS, 12)
        t = 1 - (sum(rm2.region_mean(WALL_TIME, r) for r in rm2.region_ids)
                 / sum(rm.region_mean(WALL_TIME, r) for r in rm.region_ids))
        return d3, d12, t

    (d3, d12, t), us = _timed(run, n=2)
    return ("sec6_2_npar1way_optimized", us,
            f"instr3=-{100*d3:.1f}%;instr12=-{100*d12:.1f}%;"
            f"time=-{100*t:.1f}% (paper:-36.32/-16.93/~20)")


def mpibzip2_analysis() -> Row:
    tree, rm = mpibzip2_scenario()
    az = AutoAnalyzer(tree)
    res, us = _timed(lambda: az.analyze(rm))
    total_f = sum(rm.region_mean(FLOPS, r) for r in rm.region_ids)
    f6 = rm.region_mean(FLOPS, 6) / total_f
    total_c = sum(rm.region_mean(COMM_BYTES, r) for r in rm.region_ids)
    c7 = rm.region_mean(COMM_BYTES, 7) / total_c
    return ("sec6_3_mpibzip2", us,
            f"CCR={res.disparity.ccrs};instr6={100*f6:.0f}%;"
            f"net7={100*c7:.0f}% (paper:96/50)")


def sec64_metric_comparison() -> Row:
    tree, rm = st_scenario()
    truth = {8, 11, 14}

    def run():
        out = {}
        for metric in ("crnm", "cpi", WALL_TIME):
            res = AutoAnalyzer(tree, disparity_metric=metric).analyze(rm)
            got = set(res.disparity.ccrs)
            fp = len(got - truth)
            fn = len(truth - got)
            out[metric] = (fp, fn)
        return out

    out, us = _timed(run, n=2)
    derived = ";".join(f"{m}:fp={v[0]},fn={v[1]}" for m, v in out.items())
    return ("sec6_4_metric_comparison", us, derived)


def analyzer_scaling() -> List[Row]:
    """Throughput of the lightweight analyses (the paper's 'lightweight in
    terms of the size of performance data' claim)."""
    rows = []
    rng = np.random.default_rng(0)
    for m, n in ((64, 64), (256, 128), (1024, 256)):
        v = rng.random((m, n))
        _, us = _timed(lambda: optics_cluster(v), n=3)
        rows.append((f"optics_{m}x{n}", us, f"points={m};dims={n}"))
    return rows


def all_rows() -> List[Row]:
    rows = [
        fig9_st_dissimilarity(),
        fig11_instruction_variance(),
        fig12_13_st_disparity(),
        table2_weather_example(),
        table3_dissimilarity_roots(),
        table4_disparity_roots(),
        fig14_st_optimization(),
        fig15_16_two_round(),
        npar1way_analysis(),
        npar1way_optimization(),
        mpibzip2_analysis(),
        sec64_metric_comparison(),
    ]
    rows.extend(analyzer_scaling())
    return rows


def fig15_16_two_round() -> Row:
    """§6.1.2: coarse -> fine two-round analysis."""
    from repro.scenarios import st_fine_scenario

    def run():
        tree, rm = st_fine_scenario()
        az = AutoAnalyzer(tree)
        return az.analyze(rm)

    res, us = _timed(run, n=2)
    return ("fig15_16_two_round_refinement", us,
            f"dissim_CCCR={res.dissimilarity.cccrs};"
            f"disparity_CCCR={res.disparity.cccrs} (paper: 21; 19,21)")
