"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels execute in interpret mode, so
wall-clock here measures the *reference jnp paths* (the production numbers
are the §Roofline terms); interpret-mode kernels are validated, not timed.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import chunked_attention, naive_attention
from repro.models.rwkv import wkv6_chunked, wkv6_reference

Row = Tuple[str, float, str]


def _timeit(fn, n=5):
    out = jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(n):
        out = jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / n * 1e6


def attention_paths() -> List[Row]:
    rows = []
    key = jax.random.key(0)
    B, H, dh = 1, 4, 64
    for S in (256, 1024):
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, S, H, dh), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, H, dh), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, H, dh), jnp.float32)
        pos = jnp.arange(S)
        naive = jax.jit(lambda q, k, v: naive_attention(
            q, k, v, causal=True, window=None, q_positions=pos,
            k_positions=pos))
        chunk = jax.jit(lambda q, k, v: chunked_attention(
            q, k, v, causal=True, window=None, q_positions=pos,
            k_positions=pos, q_block=256, k_block=256))
        us_n = _timeit(lambda: naive(q, k, v))
        us_c = _timeit(lambda: chunk(q, k, v))
        flops = 4.0 * B * H * S * S * dh / 2  # causal
        rows.append((f"attn_naive_S{S}", us_n,
                     f"gflops={flops/us_n/1e3:.2f}"))
        rows.append((f"attn_chunked_S{S}", us_c,
                     f"gflops={flops/us_c/1e3:.2f}"))
    return rows


def wkv_paths() -> List[Row]:
    rows = []
    key = jax.random.key(0)
    B, H, T, dh = 1, 4, 512, 64
    ks = jax.random.split(key, 5)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, dh)) for i in range(3))
    w = jax.random.uniform(ks[3], (B, T, H, dh), minval=0.9, maxval=0.999)
    u = jax.random.normal(ks[4], (H, dh)) * 0.3
    seq = jax.jit(lambda r, k, v, w: wkv6_reference(r, k, v, w, u)[0])
    chunked = jax.jit(lambda r, k, v, w: wkv6_chunked(
        r, k, v, w, u, jnp.zeros((B, H, dh, dh)), chunk=32)[0])
    us_s = _timeit(lambda: seq(r, k, v, w), n=3)
    us_c = _timeit(lambda: chunked(r, k, v, w), n=3)
    rows.append((f"wkv6_sequential_T{T}", us_s, "path=lax.scan/token"))
    rows.append((f"wkv6_chunked_T{T}", us_c,
                 f"path=matmul/chunk;speedup={us_s/us_c:.2f}x"))
    return rows


def train_step_bench() -> List[Row]:
    from repro.configs import get_arch
    from repro.data import DataConfig, device_batch
    from repro.optim import AdamWConfig, init_opt_state
    from repro.models import build
    from repro.train import make_train_step
    cfg = get_arch("st-100m").smoke
    api = build(cfg)
    params, _ = api.init(jax.random.key(0))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig()))
    batch = device_batch(DataConfig(seq_len=64, global_batch=4,
                                    vocab=cfg.vocab), 0)
    p, o = params, opt

    def run():
        nonlocal p, o
        p, o, m = step(p, o, batch)
        return m["loss"]

    us = _timeit(run, n=5)
    toks = 4 * 64
    return [("train_step_smoke", us, f"tokens_per_s={toks/us*1e6:.0f}")]


def all_rows() -> List[Row]:
    return attention_paths() + wkv_paths() + train_step_bench()
