"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the sweep
JSONs.  Usage:
    PYTHONPATH=src python scripts/render_experiments.py
prints markdown to stdout (appended to EXPERIMENTS.md by the build step).
"""
import json
import sys


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        return []


def dryrun_table(rows, title):
    out = [f"### {title}", ""]
    out.append("| arch | shape | mesh | ok | compile_s | args GB/dev | "
               "temp GB/dev | collectives (production, per scan-body) |")
    out.append("|---|---|---|---|---|---|---|---|")
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if not r.get("ok"):
            out.append(f"| {r['arch']} | {r['shape']} | - | **FAIL** | - |"
                       f" - | - | {r.get('error','')[:60]} |")
            continue
        mem = r.get("memory", {})
        coll = r.get("production_cost_raw", {}).get("coll_counts", {})
        cstr = " ".join(f"{k.split('-')[-1][:3]}:{v}"
                        for k, v in sorted(coll.items()))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | yes | "
            f"{r.get('compile_s', 0):.1f} | "
            f"{mem.get('argument_size_in_bytes', 0)/1e9:.1f} | "
            f"{mem.get('temp_size_in_bytes', 0)/1e9:.1f} | {cstr} |")
    return "\n".join(out)


def roofline_table(rows):
    out = ["| arch | shape | compute_s | memory_s | collective_s | "
           "dominant | MODEL_FLOPS | useful | roofline% | one-line next-step |"]
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    hints = {
        "compute": "increase arithmetic intensity / larger per-chip batch",
        "memory": "fuse ops on TPU (flash/WKV kernels), shrink saved "
                  "activations (SP), bf16 end-to-end",
        "collective": "reshard to cut cross-shard traffic; overlap "
                      "collectives with compute",
    }
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if not r.get("ok") or "roofline" not in r:
            continue
        t = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | "
            f"**{t['dominant']}** | {r['model_flops']:.2e} | "
            f"{t['useful_ratio']:.2f} | {100*t['roofline_fraction']:.1f}% | "
            f"{hints[t['dominant']]} |")
    return "\n".join(out)


def main():
    single = load("results/dryrun_single_pod_optimized.json")
    multi = load("results/dryrun_multi_pod_optimized.json")
    base = load("results/dryrun_single_pod_baseline.json")
    print(dryrun_table(single, "Single pod (16×16 = 256 chips), optimized "
                       "defaults"))
    print()
    print(dryrun_table(multi, "Multi-pod (2×16×16 = 512 chips), production "
                       "pass"))
    print()
    print("### Roofline — optimized defaults (single pod; per-chip terms)")
    print()
    print(roofline_table(single))
    print()
    print("### Roofline — paper-faithful baseline (pre-optimization)")
    print()
    print(roofline_table(base))


if __name__ == "__main__":
    main()
