#!/usr/bin/env python
"""Run the full AutoAnalyzer offline on a saved RegionTrace artifact.

    PYTHONPATH=src python scripts/analyze_trace.py trace.npz
    PYTHONPATH=src python scripts/analyze_trace.py trace.npz --window 0:8
    PYTHONPATH=src python scripts/analyze_trace.py trace.npz --per-window 4
    PYTHONPATH=src python scripts/analyze_trace.py trace.npz --json

Collection and analysis decoupled, the paper's deployment story: the
collecting host (a training run, a timed region sweep, a synthetic
scenario) saves a compact ``.npz`` artifact; this script rebuilds the
region tree from the artifact's schema header and replays behaviour
analysis, bottleneck location and root-cause uncovering — bit-identical
to what an in-process analysis of the same collection would have said.

Analyzer keyword arguments default to the ``analyzer_kw`` the collector
recorded in the trace header (so a corpus-emitted artifact replays under
the entry's exact configuration) and can be overridden with
``--analyzer-kw '{"threshold_frac": 0.2}'``.

Exit codes: 0 — analyzed; 2 — usage error (argparse); 3 — artifact
missing; 4 — artifact present but damaged (truncated, bit-rotted, or a
malformed header: the structured ``TraceFormatError`` is printed with the
offending member so CI logs name the corruption, not just a numpy
traceback).
"""
from __future__ import annotations

import argparse
import json
import sys


def parse_window(spec: str):
    start, _, stop = spec.partition(":")
    return (int(start) if start else 0, int(stop) if stop else None)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="path to a RegionTrace .npz artifact")
    ap.add_argument("--window", default=None, metavar="START:STOP",
                    help="analyze only this step window of the run")
    ap.add_argument("--per-window", type=int, default=None, metavar="N",
                    help="analyze the run in consecutive N-step windows")
    ap.add_argument("--analyzer-kw", default=None, metavar="JSON",
                    help="AutoAnalyzer kwargs, overriding the trace header")
    ap.add_argument("--distance-backend", default=None,
                    choices=("numpy", "jax", "pallas"),
                    help="distance backend override (default: exact numpy)")
    ap.add_argument("--json", action="store_true",
                    help="emit the verdict(s) as JSON instead of the report")
    args = ap.parse_args(argv)
    if args.window and args.per_window:
        ap.error("--window and --per-window are mutually exclusive")
    if args.per_window is not None and args.per_window < 1:
        ap.error("--per-window must be a positive step count")

    from repro.core import (AutoAnalyzer, RegionTrace, TraceFormatError,
                            render, tree_from_schema)

    try:
        trace = RegionTrace.load(args.trace)
    except FileNotFoundError as e:
        print(str(e), file=sys.stderr)
        return 3
    except TraceFormatError as e:
        print(f"corrupt trace artifact: {e}", file=sys.stderr)
        return 4
    tree = tree_from_schema(trace.schema)
    kw = dict(trace.meta.get("analyzer_kw", {}))
    if args.analyzer_kw:
        kw.update(json.loads(args.analyzer_kw))
    if args.distance_backend:
        kw["distance_backend"] = args.distance_backend
    analyzer = AutoAnalyzer(tree, **kw)

    if args.per_window:
        windows = [(s, min(s + args.per_window, trace.n_steps))
                   for s in range(0, trace.n_steps, args.per_window)]
    else:
        windows = [parse_window(args.window)] if args.window else [None]

    docs = []
    for w in windows:
        res = analyzer.analyze_trace(trace, window=w)
        label = (f"steps [{w[0]}:{w[1] if w[1] is not None else trace.n_steps})"
                 if w else f"all {trace.n_steps} steps")
        if args.json:
            docs.append({"window": label, "verdict": res.verdict.doc()})
        else:
            print(f"== {args.trace}: {trace.n_processes} shards x "
                  f"{len(trace.region_ids)} regions, {label} "
                  f"(collector: {trace.meta.get('collector', '?')}) ==")
            print(render(tree, res))
            print()
    if args.json:
        json.dump(docs if len(docs) > 1 else docs[0], sys.stdout,
                  indent=1, sort_keys=True)
        print()
    return 0


if __name__ == "__main__":
    sys.exit(main())
