#!/usr/bin/env python
"""Tail a fleet of trace spools and report cross-run deduplicated verdicts.

    PYTHONPATH=src python scripts/fleet_watch.py --root RUNS_DIR
    PYTHONPATH=src python scripts/fleet_watch.py --root RUNS_DIR --follow
    PYTHONPATH=src python scripts/fleet_watch.py --run a=/path/a --run b=/path/b
    PYTHONPATH=src python scripts/fleet_watch.py --root RUNS_DIR --index idx --json

Where ``watch_train.py`` tails one run, this script supervises many:
every immediate subdirectory of ``--root`` that contains (or grows) a
``spool.json`` becomes a tenant of one :class:`repro.fleet.FleetIngest`
— per-run analyzers behind a bounded shared worker pool, per-run
bounded window queues with drop-oldest shedding under backpressure,
integrity-checked segments with a circuit breaker that quarantines a
repeatedly corrupt run, and stall detection + spool recovery for dead
producers (``--max-stall``).  One sick tenant cannot perturb the
others' verdicts (docs/fleet.md).

Flagged window verdicts from every run feed a crash-safe
:class:`repro.fleet.VerdictIndex` (append-only journal + atomic
snapshot under ``--index DIR``; a temporary directory when omitted).
The closing report deduplicates recurring bottleneck signatures across
the fleet: one line per distinct verdict fingerprint, "seen in N runs"
— rerunning with the same persistent ``--index`` resumes its counts
exactly, even after a kill.

Without ``--follow`` the fleet drains everything flushed so far and
exits; with it, polling continues until every producer closes (or
stalls out past ``--max-stall``).

Exit codes: 0 — every run analyzed to completion; 2 — usage error;
3 — no runs found; 4 — at least one run quarantined (report printed);
5 — runs still in progress (without ``--follow``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time


def discover_runs(root: str) -> dict:
    """Immediate subdirectories of ``root`` holding a spool manifest."""
    from repro.stream import MANIFEST_NAME
    runs = {}
    for name in sorted(os.listdir(root)):
        d = os.path.join(root, name)
        if os.path.isdir(d) and os.path.exists(
                os.path.join(d, MANIFEST_NAME)):
            runs[name] = d
    return runs


def run_line(st: dict) -> str:
    events = sum(1 for e in st["events"])
    return (f"{st['run']:24s} {st['state']:12s} {st['n_steps']:5d} steps  "
            f"{st['windows']:3d} windows  {st['degraded']:2d} degraded  "
            f"{st['shed']:2d} shed  {events:2d} events")


def report_line(row: dict) -> str:
    paths = ",".join(row["paths"]) or "-"
    kinds = ",".join(row["kinds"]) or "-"
    return (f"{row['fingerprint']:24s} seen in {row['n_runs']} runs  "
            f"{row['n_windows']:3d} windows  {kinds:13s} {paths}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--root", default=None, metavar="DIR",
                    help="directory whose subdirectories are run spools")
    ap.add_argument("--run", action="append", default=[],
                    metavar="NAME=DIR",
                    help="add one run explicitly (repeatable)")
    ap.add_argument("--window", type=int, default=4, metavar="N",
                    help="tumbling window size in steps (default 4)")
    ap.add_argument("--persist", type=int, default=2, metavar="K",
                    help="consecutive flagged windows that define onset")
    ap.add_argument("--analyzer-kw", default=None, metavar="JSON",
                    help="AutoAnalyzer kwargs, overriding trace headers")
    ap.add_argument("--distance-backend", default=None,
                    choices=("numpy", "jax", "pallas"),
                    help="distance backend for every run's analyzer "
                         "(default: exact numpy)")
    ap.add_argument("--workers", type=int, default=4, metavar="N",
                    help="shared worker budget: window analyses per poll "
                         "round, fleet-wide (default 4)")
    ap.add_argument("--queue", type=int, default=8, metavar="N",
                    help="bounded per-run window queue; the oldest window "
                         "is shed past this (default 8)")
    ap.add_argument("--max-integrity-failures", type=int, default=3,
                    metavar="N",
                    help="circuit breaker: quarantine a run after N "
                         "corrupt segments / unreadable manifests "
                         "(default 3)")
    ap.add_argument("--max-stall", type=float, default=None, metavar="SEC",
                    help="presume a producer dead after SEC seconds "
                         "without progress, recover its spool, and drain "
                         "the salvaged tail")
    ap.add_argument("--index", default=None, metavar="DIR",
                    help="persist the cross-run VerdictIndex here "
                         "(journal + snapshot; reruns resume its counts). "
                         "Default: a temporary directory")
    ap.add_argument("--retain-runs", type=int, default=None, metavar="N",
                    help="age index evidence out beyond the N most "
                         "recently contributing runs (default: unbounded)")
    ap.add_argument("--journal-max-records", type=int, default=None,
                    metavar="M",
                    help="collapse the index journal behind its snapshot "
                         "once M records accumulate (default: unbounded)")
    ap.add_argument("--follow", action="store_true",
                    help="keep polling until every producer closes")
    ap.add_argument("--interval", type=float, default=1.0, metavar="SEC",
                    help="poll interval (default 1s)")
    ap.add_argument("--max-ticks", type=int, default=100_000, metavar="N",
                    help="hard bound on poll rounds (default 100000)")
    ap.add_argument("--json", action="store_true",
                    help="emit one JSON document instead of text lines")
    args = ap.parse_args(argv)
    if not args.root and not args.run:
        ap.error("need --root and/or --run")

    from repro.fleet import FleetConfig, FleetIngest, VerdictIndex

    runs = discover_runs(args.root) if args.root else {}
    for spec in args.run:
        name, _, d = spec.partition("=")
        if not d:
            ap.error(f"--run wants NAME=DIR, got {spec!r}")
        runs[name] = d
    if not runs:
        print(f"no runs found under {args.root}", file=sys.stderr)
        return 3

    kw = json.loads(args.analyzer_kw) if args.analyzer_kw else {}
    cfg = FleetConfig(window_steps=args.window, persist=args.persist,
                      analyzer_kw=tuple(sorted(kw.items())),
                      distance_backend=args.distance_backend,
                      max_workers=args.workers,
                      queue_windows=args.queue,
                      max_integrity_failures=args.max_integrity_failures,
                      max_stall=args.max_stall)
    tmp = None
    index_dir = args.index
    if index_dir is None:
        tmp = tempfile.TemporaryDirectory(prefix="repro-vindex-")
        index_dir = tmp.name
    try:
        index = VerdictIndex(index_dir, retain_runs=args.retain_runs,
                             journal_max_records=args.journal_max_records)
        fleet = FleetIngest(cfg, index=index)
        for name, d in sorted(runs.items()):
            fleet.add_run(name, d)

        resolved = fleet.tick()
        for _ in range(args.max_ticks):
            if fleet.done:
                break
            if not args.follow and resolved == 0 \
                    and not any(s.queue for s in fleet.runs.values()):
                break       # everything flushed so far is analyzed
            if args.follow:
                time.sleep(args.interval)
            resolved = fleet.tick()
        index.close()

        status = fleet.status()
        if args.json:
            json.dump(status, sys.stdout, indent=1, sort_keys=True)
            print()
        else:
            for st in status["runs"]:
                print(run_line(st))
                for e in st["events"]:
                    print(f"{'':24s} event: "
                          + json.dumps(e, sort_keys=True))
            rows = status.get("index", [])
            print(f"-- {len(rows)} distinct verdict signature(s) across "
                  f"{len(runs)} run(s)")
            for row in rows:
                print(report_line(row))
    finally:
        if tmp is not None:
            tmp.cleanup()

    states = [st["state"] for st in status["runs"]]
    if any(s == "quarantined" for s in states):
        return 4
    if not all(s == "done" for s in states):
        print("runs still in progress: "
              + ", ".join(st["run"] for st in status["runs"]
                          if st["state"] != "done"), file=sys.stderr)
        return 5
    return 0


if __name__ == "__main__":
    sys.exit(main())
