#!/usr/bin/env python
"""Run the analyzer scaling benchmarks and record/gate BENCH_analyzer.json.

    PYTHONPATH=src python scripts/run_bench.py                 # default grid
    PYTHONPATH=src python scripts/run_bench.py --grid smoke
    PYTHONPATH=src python scripts/run_bench.py --out BENCH_analyzer.json
    PYTHONPATH=src python scripts/run_bench.py --check BENCH_analyzer.json

``--check`` re-runs the baseline file's grid and exits nonzero when any
entry regresses by more than ``--factor`` (default 1.5x).  Entries whose
baseline time is below ``--min-seconds`` are reported but never fail the
check — micro-entries are timer noise, not signal.  Timings are
best-of-``--repeat`` wall clock, so the gate is meaningful on an otherwise
idle machine (CI runs the smoke grid; the committed default-grid baseline
documents the reference machine's trajectory).  Best-of-5 with an untimed
warmup pass; the default factor (2x) and noise floor (2 ms) are
calibrated to the observed same-code jitter of a small shared container
(CPU-steal episodes push even 30 ms entries past 1.5x run-to-run) — a
real algorithmic regression on the entries this gate protects shows up
well past 2x.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# `python scripts/run_bench.py` puts scripts/ (not the repo root) on
# sys.path; the benchmarks package lives at the root.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def _requirement_met(requires) -> bool:
    """An entry's ``requires`` is an importable module name (or None)."""
    if not requires:
        return True
    try:
        __import__(requires)
        return True
    except ImportError:
        return False


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--grid", choices=("smoke", "default"), default="default")
    ap.add_argument("--repeat", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="write results JSON here (e.g. BENCH_analyzer.json)")
    ap.add_argument("--check", metavar="BASELINE", default=None,
                    help="compare against a committed baseline JSON; exit "
                         "nonzero on regression")
    ap.add_argument("--factor", type=float, default=2.0,
                    help="regression threshold for --check (default 2x, "
                         "calibrated to shared-container jitter)")
    ap.add_argument("--min-seconds", type=float, default=2e-3,
                    help="baseline entries faster than this never fail "
                         "--check (timer noise floor)")
    args = ap.parse_args(argv)

    from benchmarks.analyzer_bench import run_grid

    grid = args.grid
    baseline = None
    if args.check:
        with open(args.check) as f:
            baseline = json.load(f)
        grid = baseline.get("meta", {}).get("grid", grid)

    entries = run_grid(grid, repeat=args.repeat, seed=args.seed)
    doc = {
        "meta": {"grid": grid, "repeat": args.repeat, "seed": args.seed,
                 "unix_time": int(time.time())},
        "entries": entries,
    }

    width = max(len(n) for n in entries) + 2
    print(f"{'entry':{width}s} {'ms':>10s}")
    for name, e in entries.items():
        print(f"{name:{width}s} {e['seconds'] * 1e3:10.3f}")

    if args.out:
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.out}")

    if baseline is None:
        return 0

    base_entries = baseline.get("entries", {})
    missing = sorted(set(base_entries) - set(entries))
    # Baseline entries that declare a requirement this machine cannot
    # meet (e.g. jax-backed seedrows on a numpy-only install) are
    # skipped, not failed — the gate must stay usable everywhere.
    skipped = [n for n in missing
               if not _requirement_met(base_entries[n].get("requires"))]
    if skipped:
        missing = [n for n in missing if n not in set(skipped)]
        print(f"skipping {len(skipped)} baseline entries with unmet "
              f"requirements: {skipped}")
        base_entries = {n: e for n, e in base_entries.items()
                        if n not in set(skipped)}
    if missing:
        print(f"baseline entries not produced by this run: {missing}",
              file=sys.stderr)
        return 2
    regressions = []
    for name, base in sorted(base_entries.items()):
        now = entries[name]["seconds"]
        ref = base["seconds"]
        ratio = now / ref if ref > 0 else float("inf")
        flag = ""
        if ratio > args.factor:
            if ref < args.min_seconds:
                flag = "  (noise floor, ignored)"
            else:
                regressions.append((name, ref, now, ratio))
                flag = "  REGRESSION"
        if flag:
            print(f"{name}: {ref * 1e3:.3f} ms -> {now * 1e3:.3f} ms "
                  f"({ratio:.2f}x){flag}")
    if regressions:
        print(f"{len(regressions)} entries regressed more than "
              f"{args.factor}x", file=sys.stderr)
        return 1
    print(f"check ok: no entry regressed more than {args.factor}x "
          f"vs {args.check}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
