#!/usr/bin/env python
"""Dump full analyzer verdicts for every *synthetic* corpus entry to JSON,
or diff the current verdicts against a committed baseline.

    PYTHONPATH=src python scripts/snapshot_verdicts.py out.json [--seed N]
    PYTHONPATH=src python scripts/snapshot_verdicts.py --check VERDICTS.json

The corpus gate (scripts/run_corpus.py) only scores pass/fail; this dump
captures everything a verdict contains — partitions, CCR/CCCR paths, cause
attributes, per-path causes, dissimilarity severity, composite_s, disparity
severities — so a hot-path change can be proven output-preserving by
diffing two snapshots.  Runtime/train-backend entries are wall-clock noisy
and are excluded.

``--check`` compares the live verdicts against a baseline file (the repo
commits one at VERDICTS_synthetic.json): every baseline entry must still
exist and match bit-for-bit; entries added since the baseline are listed
but allowed (regenerate the baseline when adding entries or intentionally
changing the analyzer).
"""
from __future__ import annotations

import argparse
import json
import sys


def snapshot(seed: int, distance_backend: str = None) -> dict:
    from repro.core import AutoAnalyzer
    from repro.scenarios import corpus_entries

    out = {}
    for entry in corpus_entries(backend="synthetic"):
        tree, collector = entry.build(seed)
        kw = dict(entry.analyzer_kw)
        if distance_backend is not None:
            kw["distance_backend"] = distance_backend
        analyzer = AutoAnalyzer(tree, **kw)
        res = analyzer.analyze_collector(collector)
        out[entry.name] = {
            **res.verdict.doc(),
            "dissimilarity_severity": res.dissimilarity.severity,
            "composite_s": res.dissimilarity.composite_s,
            "baseline_n_clusters": res.dissimilarity.baseline.n_clusters,
            "baseline_partition": [list(g) for g in
                                   res.dissimilarity.baseline
                                   .partition_signature],
            "disparity_severities": {str(k): int(s) for k, s in
                                     sorted(res.disparity.severities.items())},
        }
    return out


def check(baseline_path: str, seed: int, distance_backend: str = None) -> int:
    with open(baseline_path) as f:
        baseline = json.load(f)
    current = snapshot(seed, distance_backend)
    drifted = []
    for name, want in sorted(baseline.items()):
        got = current.get(name)
        if got is None:
            drifted.append((name, "entry missing from current corpus"))
        elif got != want:
            detail = ", ".join(k for k in sorted(set(want) | set(got))
                               if got.get(k) != want.get(k))
            drifted.append((name, f"fields drifted: {detail}"))
    new = sorted(set(current) - set(baseline))
    if new:
        print(f"{len(new)} entries not in baseline (ok, regenerate to pin): "
              f"{new}")
    if drifted:
        print(f"VERDICT DRIFT vs {baseline_path} (seed {seed}):")
        for name, why in drifted:
            print(f"  {name}: {why}")
        return 1
    print(f"{len(baseline)} baseline entries bit-identical "
          f"(seed {seed}) vs {baseline_path}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("out", nargs="?", default=None,
                    help="snapshot output path (omit with --check)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check", default=None, metavar="BASELINE",
                    help="diff live verdicts against this snapshot; exit "
                         "1 on any drift")
    ap.add_argument("--distance-backend", default=None,
                    choices=("numpy", "jax", "pallas"),
                    help="override every entry's distance backend; with "
                         "--check this proves the accelerated lane "
                         "verdict-equal to the exact baseline")
    args = ap.parse_args(argv)
    if args.check:
        if args.out:
            ap.error("--check does not write a snapshot; drop the output "
                     "path (regenerate first, then --check, if you want "
                     "both)")
        return check(args.check, args.seed, args.distance_backend)
    if not args.out:
        ap.error("either an output path or --check is required")
    doc = snapshot(args.seed, args.distance_backend)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out} ({len(doc)} entries)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
