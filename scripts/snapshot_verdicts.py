#!/usr/bin/env python
"""Dump full analyzer verdicts for every *synthetic* corpus entry to JSON.

    PYTHONPATH=src python scripts/snapshot_verdicts.py out.json [--seed N]

The corpus gate (scripts/run_corpus.py) only scores pass/fail; this dump
captures everything a verdict contains — partitions, CCR/CCCR paths, cause
attributes, per-path causes, dissimilarity severity, composite_s, disparity
severities — so a hot-path change can be proven output-preserving by
diffing two snapshots.  Runtime-backend entries are wall-clock noisy and
are excluded.
"""
from __future__ import annotations

import argparse
import json
import sys


def snapshot(seed: int) -> dict:
    from repro.core import AutoAnalyzer
    from repro.scenarios import corpus_entries

    out = {}
    for entry in corpus_entries(backend="synthetic"):
        tree, collector = entry.build(seed)
        analyzer = AutoAnalyzer(tree, **dict(entry.analyzer_kw))
        res = analyzer.analyze_collector(collector)
        v = res.verdict
        out[entry.name] = {
            "dissimilar": v.dissimilar,
            "dissimilarity_paths": sorted(v.dissimilarity_paths),
            "dissimilarity_ccr_paths": sorted(v.dissimilarity_ccr_paths),
            "disparity_paths": sorted(v.disparity_paths),
            "disparity_ccr_paths": sorted(v.disparity_ccr_paths),
            "cause_attributes": sorted(v.cause_attributes),
            "dissimilarity_cause_attributes":
                sorted(v.dissimilarity_cause_attributes),
            "per_path_causes": [[p, list(a)] for p, a in v.per_path_causes],
            "dissimilarity_severity": res.dissimilarity.severity,
            "composite_s": res.dissimilarity.composite_s,
            "baseline_n_clusters": res.dissimilarity.baseline.n_clusters,
            "baseline_partition": [list(g) for g in
                                   res.dissimilarity.baseline
                                   .partition_signature],
            "disparity_severities": {str(k): int(s) for k, s in
                                     sorted(res.disparity.severities.items())},
        }
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("out")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    doc = snapshot(args.seed)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out} ({len(doc)} entries)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
