#!/usr/bin/env python
"""Run the golden fault-injection corpus end-to-end and report scores.

    PYTHONPATH=src python scripts/run_corpus.py [--seed N] [--backend B]
                                                [--list] [--entry NAME ...]

Prints a per-entry precision/recall table and exits nonzero when any entry
misses its ground-truth bottleneck paths or cause attributes — usable
directly as a CI gate.

Recovery-backend entries (``--backend recovery``) run the closed
mitigation loop end-to-end (docs/mitigation.md): live per-step verdicts
drive a MitigationPolicy, and the ``recov`` column reports the window the
action fired at against the entry's time-to-mitigate bound (got/want,
like ``onset``); the detail line below adds the action kind and the
post-mitigation clean-window tail.

Chaos-backend entries (``--backend chaos``, docs/robustness.md) inject
deterministic infrastructure faults into the pipeline itself; the
``chaos`` column reports matched/comparable window verdicts between the
recovered chaos run and a clean run of the same scenario (every
comparable window must match bit-for-bit), and the detail line adds the
quarantine/adoption/stall/fallback accounting.
"""
from __future__ import annotations

import argparse
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend",
                    choices=("synthetic", "runtime", "train", "recovery",
                             "chaos"),
                    default=None, help="restrict to one backend")
    ap.add_argument("--entry", action="append", default=None,
                    help="run only these entries (repeatable)")
    ap.add_argument("--list", action="store_true",
                    help="list registered entries and exit")
    ap.add_argument("--train-trace-dir", default=None, metavar="DIR",
                    help="save each train-backend entry's RegionTrace "
                         "artifact here (one training run serves both the "
                         "gate and the artifact)")
    ap.add_argument("--train-spool-dir", default=None, metavar="DIR",
                    help="collect train-backend entries through a "
                         "TraceSpool under this base directory (streaming "
                         "collection; each run's spool path is printed so "
                         "CI can replay/byte-compare it)")
    args = ap.parse_args(argv)

    from repro.scenarios import run_entry_robust, select_entries
    if args.train_spool_dir:
        from repro.scenarios import corpus as corpus_mod
        corpus_mod.TRAIN_SPOOL_BASE = args.train_spool_dir

    try:
        entries = select_entries(backend=args.backend, names=args.entry)
    except ValueError as e:  # unknown entry, or one excluded by --backend
        print(str(e), file=sys.stderr)
        return 2

    if args.list:
        for e in entries:
            print(f"{e.name:44s} [{e.backend:9s}] {e.truth.kind:13s} "
                  f"{e.description}")
        return 0

    results = []
    for e in entries:
        r = run_entry_robust(e, seed=args.seed)
        results.append((r, r.attempt_walls))
        if args.train_trace_dir and e.backend == "train":
            trace = r.collector.trainer.trace
            path = os.path.join(args.train_trace_dir,
                                e.name.replace("/", "-") + ".npz")
            os.makedirs(args.train_trace_dir, exist_ok=True)
            print(f"saved trace artifact: {trace.save(path)}")
        if args.train_spool_dir and e.backend == "train":
            # the kept run's spool (a retry spools separately)
            print(f"spool: {e.name} -> "
                  f"{r.collector.trainer.tcfg.trace_spool_dir}")
    if not results:
        print("no entries selected", file=sys.stderr)
        return 2
    wname = max(len(r.entry.name) for r, _ in results) + 2
    print(f"{'entry':{wname}s} {'kind':13s} {'prec':>6s} {'recall':>6s} "
          f"{'causes':>6s} {'onset':>7s} {'recov':>7s} {'chaos':>7s} "
          f"{'wall_s':>7s}  status")
    print("-" * (wname + 76))
    failures = 0
    for r, walls in results:
        status = "ok" if r.passed else "FAIL"
        if not r.passed:
            failures += 1
        want = r.entry.expect_onset_window
        onset = "-" if want is None else f"{r.onset_window}/{want}"
        # recovery got/want: the window the first action fired at vs the
        # entry's time-to-mitigate bound (details printed below)
        rwant = r.entry.recovery
        recov = "-" if rwant is None \
            else f"{r.mitigation_window}/{rwant.mitigate_by_window}"
        # chaos got/want: matched vs comparable clean-run windows (every
        # comparable window must reproduce the clean verdict exactly)
        o = r.chaos_outcome
        chaos = "-" if o is None else f"{o.matched}/{o.comparable}"
        print(f"{r.entry.name:{wname}s} {r.entry.truth.kind:13s} "
              f"{r.precision:6.2f} {r.recall:6.2f} {r.cause_recall:6.2f} "
              f"{onset:>7s} {recov:>7s} {chaos:>7s} {sum(walls):7.3f}  "
              f"{status}")
        if rwant is not None:
            print(f"{'':{wname}s}   recovery: got {r.recovery_kind} at "
                  f"window {r.mitigation_window}, clean tail "
                  f"{r.clean_after} (want {rwant.kind} by window "
                  f"{rwant.mitigate_by_window}, clean >= "
                  f"{rwant.clean_windows})")
        if o is not None:
            fb = (f", fell back step {o.fallback_from}->{o.restored_step}"
                  if o.fallback_from is not None else "")
            print(f"{'':{wname}s}   chaos: survived={o.survived} "
                  f"quarantined={o.quarantined} adopted={o.adopted} "
                  f"degraded={o.degraded} stalled={o.stalled}{fb}")
            for msg in (r.chaos_failures or ()):
                print(f"{'':{wname}s}   chaos FAIL: {msg}")
        if len(walls) > 1:
            # a retried wall-clock entry: report every attempt, not just
            # the one whose result was kept
            print(f"{'':{wname}s}   retried: attempt wall_s "
                  + ", ".join(f"{w:.3f}" for w in walls))
        if r.missed:
            print(f"{'':{wname}s}   missed: {sorted(r.missed)}")
        if not r.passed and r.spurious:
            print(f"{'':{wname}s}   spurious: {sorted(r.spurious)}")
        want = r.entry.truth.cause_attributes
        if want and not want <= r.causes_found:
            print(f"{'':{wname}s}   causes wanted {sorted(want)}, "
                  f"got {sorted(r.causes_found)} at the planted paths "
                  f"(globally: {sorted(r.verdict.cause_attributes)})")
    print("-" * (wname + 76))
    print(f"{len(results) - failures}/{len(results)} entries passed "
          f"(seed {args.seed})")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
