#!/usr/bin/env python
"""Run the golden fault-injection corpus end-to-end and report scores.

    PYTHONPATH=src python scripts/run_corpus.py [--seed N] [--backend B]
                                                [--jobs N]
                                                [--list] [--entry NAME ...]

Prints a per-entry precision/recall table and exits nonzero when any entry
misses its ground-truth bottleneck paths or cause attributes — usable
directly as a CI gate.

``--jobs N`` fans the entries out over a process pool (spawn context —
safe alongside JAX).  Workers receive entry *names* and return plain
result rows, so nothing unpicklable crosses the process boundary, and
the table is printed in deterministic entry order regardless of which
worker finishes first: the output is byte-identical to a sequential run
apart from the wall_s column.

Recovery-backend entries (``--backend recovery``) run the closed
mitigation loop end-to-end (docs/mitigation.md): live per-step verdicts
drive a MitigationPolicy, and the ``recov`` column reports the window the
action fired at against the entry's time-to-mitigate bound (got/want,
like ``onset``); the detail line below adds the action kind and the
post-mitigation clean-window tail.

Chaos-backend entries (``--backend chaos``, docs/robustness.md) inject
deterministic infrastructure faults into the pipeline itself; the
``chaos`` column reports matched/comparable window verdicts between the
recovered chaos run and a clean run of the same scenario (every
comparable window must match bit-for-bit), and the detail line adds the
quarantine/adoption/stall/fallback accounting.

Fleet-backend entries (``--backend fleet``, docs/fleet.md) attack one
tenant of an eight-run FleetIngest; the same ``chaos`` column then gates
*isolation* — every unaffected run's windows must match a solo analysis
of the same spool — and the detail line adds the shed/quarantined-run
accounting.

Serving-backend entries (``--backend serving``, docs/serving.md) drive
deterministic traffic through the cost-model ServeEngine with per-step
fault injection; the ``serve`` column reports completed requests against
the entry's ServingTruth floor (got/want) — locating the bottleneck only
counts if the engine also served the traffic.
"""
from __future__ import annotations

import argparse
import os
import sys


def run_one(name: str, seed: int, train_trace_dir=None,
            train_spool_dir=None, distance_backend=None) -> dict:
    """Run one corpus entry by name and reduce the result to a plain
    row dict (the only thing that crosses the --jobs process boundary:
    CorpusRunResult holds closures and collectors that do not pickle)."""
    from repro.scenarios import run_entry_robust, select_entries
    if train_spool_dir:
        from repro.scenarios import corpus as corpus_mod
        corpus_mod.TRAIN_SPOOL_BASE = train_spool_dir
    entry = select_entries(names=[name])[0]
    overrides = ({"distance_backend": distance_backend}
                 if distance_backend else None)
    r = run_entry_robust(entry, seed=seed, analyzer_overrides=overrides)
    notes = []
    if train_trace_dir and entry.backend == "train":
        trace = r.collector.trainer.trace
        path = os.path.join(train_trace_dir,
                            name.replace("/", "-") + ".npz")
        os.makedirs(train_trace_dir, exist_ok=True)
        notes.append(f"saved trace artifact: {trace.save(path)}")
    if train_spool_dir and entry.backend == "train":
        # the kept run's spool (a retry spools separately)
        notes.append(f"spool: {name} -> "
                     f"{r.collector.trainer.tcfg.trace_spool_dir}")
    o = r.chaos_outcome
    rwant = entry.recovery
    return {
        "name": name,
        "kind": entry.truth.kind,
        "passed": r.passed,
        "precision": r.precision,
        "recall": r.recall,
        "cause_recall": r.cause_recall,
        "walls": list(r.attempt_walls),
        "onset": (None if entry.expect_onset_window is None
                  else [r.onset_window, entry.expect_onset_window]),
        "recov": (None if rwant is None
                  else [r.mitigation_window, rwant.mitigate_by_window]),
        "recovery": (None if rwant is None else {
            "got_kind": r.recovery_kind, "window": r.mitigation_window,
            "clean_after": r.clean_after, "want_kind": rwant.kind,
            "by_window": rwant.mitigate_by_window,
            "clean_windows": rwant.clean_windows}),
        "chaos": (None if o is None else {
            "survived": o.survived, "quarantined": o.quarantined,
            "adopted": o.adopted, "degraded": o.degraded,
            "stalled": o.stalled, "shed": o.shed,
            "matched": o.matched, "comparable": o.comparable,
            "fallback_from": o.fallback_from,
            "restored_step": o.restored_step}),
        "chaos_failures": list(r.chaos_failures or ()),
        "serve": (None if entry.serving is None
                  else [r.completed, entry.serving.min_completed]),
        "missed": sorted(r.missed),
        "spurious": sorted(r.spurious),
        "causes_wanted": sorted(entry.truth.cause_attributes),
        "causes_found": sorted(r.causes_found),
        "causes_global": sorted(r.verdict.cause_attributes),
        "notes": notes,
    }


def _print_row(row: dict, wname: int) -> None:
    status = "ok" if row["passed"] else "FAIL"
    fmt = lambda gw: "-" if gw is None else f"{gw[0]}/{gw[1]}"
    o = row["chaos"]
    chaos = "-" if o is None else f"{o['matched']}/{o['comparable']}"
    print(f"{row['name']:{wname}s} {row['kind']:13s} "
          f"{row['precision']:6.2f} {row['recall']:6.2f} "
          f"{row['cause_recall']:6.2f} {fmt(row['onset']):>7s} "
          f"{fmt(row['recov']):>7s} {chaos:>7s} "
          f"{fmt(row.get('serve')):>7s} "
          f"{sum(row['walls']):7.3f}  {status}")
    pad = " " * wname
    rec = row["recovery"]
    if rec is not None:
        print(f"{pad}   recovery: got {rec['got_kind']} at window "
              f"{rec['window']}, clean tail {rec['clean_after']} "
              f"(want {rec['want_kind']} by window {rec['by_window']}, "
              f"clean >= {rec['clean_windows']})")
    if o is not None:
        fb = (f", fell back step {o['fallback_from']}->"
              f"{o['restored_step']}"
              if o["fallback_from"] is not None else "")
        shed = f" shed={o['shed']}" if o["shed"] else ""
        print(f"{pad}   chaos: survived={o['survived']} "
              f"quarantined={o['quarantined']} adopted={o['adopted']} "
              f"degraded={o['degraded']} stalled={o['stalled']}"
              f"{shed}{fb}")
        for msg in row["chaos_failures"]:
            print(f"{pad}   chaos FAIL: {msg}")
    if len(row["walls"]) > 1:
        # a retried wall-clock entry: report every attempt, not just
        # the one whose result was kept
        print(f"{pad}   retried: attempt wall_s "
              + ", ".join(f"{w:.3f}" for w in row["walls"]))
    if row["missed"]:
        print(f"{pad}   missed: {row['missed']}")
    if not row["passed"] and row["spurious"]:
        print(f"{pad}   spurious: {row['spurious']}")
    want = row["causes_wanted"]
    if want and not set(want) <= set(row["causes_found"]):
        print(f"{pad}   causes wanted {want}, got {row['causes_found']} "
              f"at the planted paths (globally: {row['causes_global']})")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend",
                    choices=("synthetic", "runtime", "train", "recovery",
                             "chaos", "fleet", "serving"),
                    default=None, help="restrict to one backend")
    ap.add_argument("--entry", action="append", default=None,
                    help="run only these entries (repeatable)")
    ap.add_argument("--jobs", type=int, default=1, metavar="N",
                    help="run entries on an N-process pool (spawn "
                         "context); output stays in entry order")
    ap.add_argument("--list", action="store_true",
                    help="list registered entries and exit")
    ap.add_argument("--train-trace-dir", default=None, metavar="DIR",
                    help="save each train-backend entry's RegionTrace "
                         "artifact here (one training run serves both the "
                         "gate and the artifact)")
    ap.add_argument("--train-spool-dir", default=None, metavar="DIR",
                    help="collect train-backend entries through a "
                         "TraceSpool under this base directory (streaming "
                         "collection; each run's spool path is printed so "
                         "CI can replay/byte-compare it)")
    ap.add_argument("--distance-backend", default=None,
                    choices=("numpy", "jax", "pallas"),
                    help="override every entry's analyzer distance "
                         "backend (accelerated-lane gate: jax/pallas "
                         "must reproduce the exact-lane verdicts)")
    args = ap.parse_args(argv)
    if args.jobs < 1:
        print("--jobs must be >= 1", file=sys.stderr)
        return 2

    from repro.scenarios import select_entries
    try:
        entries = select_entries(backend=args.backend, names=args.entry)
    except ValueError as e:  # unknown entry, or one excluded by --backend
        print(str(e), file=sys.stderr)
        return 2

    if args.list:
        for e in entries:
            print(f"{e.name:44s} [{e.backend:9s}] {e.truth.kind:13s} "
                  f"{e.description}")
        return 0
    if not entries:
        print("no entries selected", file=sys.stderr)
        return 2

    names = [e.name for e in entries]
    if args.jobs > 1 and len(names) > 1:
        import multiprocessing as mp
        from concurrent.futures import ProcessPoolExecutor
        ctx = mp.get_context("spawn")   # fork is unsafe alongside JAX
        with ProcessPoolExecutor(max_workers=min(args.jobs, len(names)),
                                 mp_context=ctx) as pool:
            futures = [pool.submit(run_one, n, args.seed,
                                   args.train_trace_dir,
                                   args.train_spool_dir,
                                   args.distance_backend) for n in names]
            # collect in submit order: the table is deterministic no
            # matter which worker finishes first
            rows = [f.result() for f in futures]
    else:
        rows = [run_one(n, args.seed, args.train_trace_dir,
                        args.train_spool_dir, args.distance_backend)
                for n in names]

    for row in rows:
        for note in row["notes"]:
            print(note)
    wname = max(len(n) for n in names) + 2
    print(f"{'entry':{wname}s} {'kind':13s} {'prec':>6s} {'recall':>6s} "
          f"{'causes':>6s} {'onset':>7s} {'recov':>7s} {'chaos':>7s} "
          f"{'serve':>7s} {'wall_s':>7s}  status")
    print("-" * (wname + 84))
    failures = sum(1 for row in rows if not row["passed"])
    for row in rows:
        _print_row(row, wname)
    print("-" * (wname + 84))
    print(f"{len(rows) - failures}/{len(rows)} entries passed "
          f"(seed {args.seed})")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
