#!/usr/bin/env python
"""Tail a trace spool — live or finished — and stream per-window verdicts.

    PYTHONPATH=src python scripts/watch_train.py SPOOL_DIR
    PYTHONPATH=src python scripts/watch_train.py SPOOL_DIR --follow
    PYTHONPATH=src python scripts/watch_train.py SPOOL_DIR --window 8 --json
    PYTHONPATH=src python scripts/watch_train.py SPOOL_DIR --finalize out.npz

The collection side (a Trainer with ``trace_spool_dir`` set, or anything
appending to a :class:`repro.stream.TraceSpool`) flushes step segments as
the run goes; this script re-reads the spool manifest, runs the full
AutoAnalyzer on each completed tumbling window, prints one verdict line
per window, and reports the **onset**: the first window whose bottleneck
verdict persisted ``--persist`` consecutive windows — so a drifting fault
is localized in time while the run is still going.  With overlapping
windows (``--stride`` smaller than ``--window``) the reported onset step
is additionally bisected *inside* the first flagged window, down to the
exact step whose inclusion first flips the verdict.

Analyzer keyword arguments default to the ``analyzer_kw`` the collector
recorded in the trace header (same resolution as ``analyze_trace.py``)
and can be overridden with ``--analyzer-kw '{"threshold_frac": 0.2}'``.

``--follow`` keeps polling until the producer closes the spool; without it
the script processes everything flushed so far and exits (nonzero if the
spool is still incomplete, so CI can assert it saw a whole run).
``--follow --max-stall SEC`` bounds the wait: when the spool makes no
progress for SEC seconds the producer is presumed dead and the script
exits rather than tailing a corpse forever (exit code 4 below; rerun
with ``--recover`` to salvage and re-analyze).
``--recover`` runs :meth:`TraceSpool.recover` before tailing — torn
``.tmp`` residue is quarantined, a crash-orphaned trailing segment is
adopted, and the quarantine/adopt/lost-range event log is printed —
then analyzes the sealed manifest like any complete spool.
``--finalize PATH`` converts the complete spool into the classic
single-``.npz`` artifact — byte-identical to the monolithic save of the
same run.

Windows the analyzer could not judge (a quarantined segment's range, a
non-finite sample burst) print as ``DEGRADED`` with the reason — they are
reported, never silently skipped, and never count toward onset.

Exit codes: 0 — complete run analyzed; 2 — usage error (argparse);
3 — spool missing/invalid, or run still in progress without ``--follow``;
4 — ``--max-stall`` exceeded, producer presumed dead.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def window_line(wv) -> str:
    if wv.degraded:
        return (f"window {wv.index:3d}  steps [{wv.start}:{wv.stop})  "
                f"{'DEGRADED':26s} {wv.reason}")
    kinds = ",".join(sorted(wv.kinds)) or "-"
    paths = ",".join(wv.paths()) or "-"
    return (f"window {wv.index:3d}  steps [{wv.start}:{wv.stop})  "
            f"{kinds:26s} {paths}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("spool", help="spool directory (contains spool.json)")
    ap.add_argument("--window", type=int, default=4, metavar="N",
                    help="tumbling window size in steps (default 4)")
    ap.add_argument("--stride", type=int, default=None, metavar="N",
                    help="window stride (default: window size; a stride "
                         "smaller than the window overlaps windows and "
                         "bisects the onset down to a step)")
    ap.add_argument("--persist", type=int, default=2, metavar="K",
                    help="consecutive flagged windows that define onset")
    ap.add_argument("--kind", choices=("dissimilarity", "disparity"),
                    default=None,
                    help="restrict onset detection to one bottleneck kind")
    ap.add_argument("--analyzer-kw", default=None, metavar="JSON",
                    help="AutoAnalyzer kwargs, overriding the trace header")
    ap.add_argument("--distance-backend", default=None,
                    choices=("numpy", "jax", "pallas"),
                    help="distance backend for the per-window analyzer "
                         "(default: exact numpy)")
    ap.add_argument("--follow", action="store_true",
                    help="keep polling until the producer closes the spool")
    ap.add_argument("--interval", type=float, default=1.0, metavar="SEC",
                    help="poll interval with --follow (default 1s)")
    ap.add_argument("--max-stall", type=float, default=None, metavar="SEC",
                    help="with --follow: exit 4 (producer presumed dead) "
                         "when the spool makes no progress for SEC seconds")
    ap.add_argument("--recover", action="store_true",
                    help="run TraceSpool.recover on the spool before "
                         "tailing (salvage a crashed producer's residue: "
                         "torn tmps quarantined, orphan segments adopted) "
                         "and print the quarantine/adopt event log")
    ap.add_argument("--json", action="store_true",
                    help="emit one JSON document instead of text lines")
    ap.add_argument("--finalize", default=None, metavar="PATH",
                    help="after a complete run, write the classic "
                         "single-.npz artifact here (byte-identical to "
                         "the monolithic save)")
    args = ap.parse_args(argv)
    if args.window < 1:
        ap.error("--window must be a positive step count")

    import os

    from repro.stream import (MANIFEST_NAME, OnlineAnalyzer,
                              ProducerStalledError, SpooledTrace,
                              StallDetector, TraceSpool)

    if args.recover:
        # salvage first, then tail the sealed manifest like any other
        # complete spool; the event log says exactly what was kept
        try:
            event = TraceSpool.recover(args.spool)
        except (ValueError, OSError) as e:
            print(str(e), file=sys.stderr)
            return 3
        for q in event["quarantined"]:
            print(f"recover: quarantined {q['file']} ({q['reason']})")
        for a in event["adopted"]:
            print(f"recover: adopted {a}")
        for lo, hi in event["lost_ranges"]:
            print(f"recover: lost steps [{lo}:{hi})")
        print(f"recover: sealed at {event['n_steps']} steps")

    # A live run has no manifest until its first chunk flushes; --follow
    # waits for it rather than dying at startup — but a producer that
    # died *before* its first flush must not be tailed forever either,
    # so --max-stall bounds this wait too.  A *present* but invalid
    # manifest (foreign file, newer version) still aborts.
    waited = 0.0
    while True:
        try:
            spooled = SpooledTrace(args.spool)
            break
        except ValueError as e:
            missing = not os.path.exists(
                os.path.join(args.spool, MANIFEST_NAME))
            if not (args.follow and missing):
                print(str(e), file=sys.stderr)
                return 3
            if args.max_stall is not None and waited >= args.max_stall:
                print(f"{args.spool}: no spool manifest after "
                      f"{waited:.1f}s — producer presumed dead",
                      file=sys.stderr)
                return 4
            time.sleep(args.interval)
            waited += args.interval
    kw = json.loads(args.analyzer_kw) if args.analyzer_kw else None
    online = OnlineAnalyzer(window_steps=args.window, stride=args.stride,
                            persist=args.persist, analyzer_kw=kw,
                            distance_backend=args.distance_backend)

    detector = (StallDetector(args.max_stall, base_interval=args.interval)
                if args.follow and args.max_stall is not None else None)
    while True:
        for wv in online.poll(spooled):
            if not args.json:
                print(window_line(wv), flush=True)
        if spooled.complete or not args.follow:
            break
        if detector is not None:
            try:
                delay = detector.observe(spooled)
            except ProducerStalledError as e:
                print(str(e), file=sys.stderr)
                return 4
            time.sleep(delay)
        else:
            time.sleep(args.interval)

    onset = online.onset_report(args.kind)
    if args.json:
        doc = {
            "spool": args.spool,
            "complete": spooled.complete,
            "n_steps": spooled.n_steps,
            "window_steps": args.window,
            "persist": args.persist,
            "windows": [
                ({"index": wv.index, "steps": [wv.start, wv.stop],
                  "degraded": True, "reason": wv.reason,
                  "detail": wv.detail}
                 if wv.degraded else
                 {"index": wv.index, "steps": [wv.start, wv.stop],
                  "kinds": sorted(wv.kinds),
                  "verdict": wv.verdict.doc()})
                for wv in online.log.windows],
            "onset": onset,
        }
        json.dump(doc, sys.stdout, indent=1, sort_keys=True)
        print()
    else:
        if onset is not None:
            print(f"onset: window {onset['onset_window']} (step "
                  f"{onset['onset_step']}; kinds "
                  f"{','.join(onset['kinds'])}; paths "
                  f"{','.join(onset['paths']) or '-'})")
        else:
            print(f"onset: none ({len(online.log.windows)} windows, "
                  f"persist {args.persist})")
    if not spooled.complete:
        print(f"{args.spool}: run still in progress "
              f"({spooled.n_steps} steps flushed)", file=sys.stderr)
        return 3
    if args.finalize:
        # stderr keeps --json stdout a single parseable document
        print(f"finalized: {spooled.finalize(args.finalize)}",
              file=sys.stderr if args.json else sys.stdout)
    return 0


if __name__ == "__main__":
    sys.exit(main())
